#!/usr/bin/env bash
# One-command verification: runs the tier-1 test suite exactly as CI does.
#   ./scripts/check.sh                     # full suite
#   ./scripts/check.sh tests/test_api.py   # extra pytest args pass through
#   ./scripts/check.sh --lint              # ruff lint (the CI lint job)
#   ./scripts/check.sh --tripwire          # skipped-test budget check
#   ./scripts/check.sh --cov               # suite + quant/train coverage
#                                          # floor (needs pytest-cov)
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--lint" ]]; then
    shift
    if python -m ruff --version >/dev/null 2>&1; then
        exec python -m ruff check src tests benchmarks "$@"
    fi
    echo "check.sh --lint: ruff not installed; skipping locally" \
         "(CI installs it from requirements-dev.txt)" >&2
    exit 0
fi

if [[ "${1:-}" == "--tripwire" ]]; then
    shift
    exec python scripts/skip_tripwire.py "$@"
fi

if [[ "${1:-}" == "--cov" ]]; then
    shift
    # coverage floor on the quantization + training packages (the PR-10
    # QAT surface); the floor is a tripwire against whole untested
    # modules landing, not a per-line style gate
    if ! python -c "import pytest_cov" >/dev/null 2>&1; then
        echo "check.sh --cov: pytest-cov not installed; running plain" \
             "suite (CI installs it from requirements-dev.txt)" >&2
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
            exec python -m pytest -x -q "$@"
    fi
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q \
        --cov=repro.quant --cov=repro.train \
        --cov-report=term-missing --cov-fail-under=80 "$@"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
