#!/usr/bin/env bash
# One-command verification: runs the tier-1 test suite exactly as CI does.
#   ./scripts/check.sh                     # full suite
#   ./scripts/check.sh tests/test_api.py   # extra pytest args pass through
#   ./scripts/check.sh --lint              # ruff lint (the CI lint job)
#   ./scripts/check.sh --tripwire          # skipped-test budget check
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--lint" ]]; then
    shift
    if python -m ruff --version >/dev/null 2>&1; then
        exec python -m ruff check src tests benchmarks "$@"
    fi
    echo "check.sh --lint: ruff not installed; skipping locally" \
         "(CI installs it from requirements-dev.txt)" >&2
    exit 0
fi

if [[ "${1:-}" == "--tripwire" ]]; then
    shift
    exec python scripts/skip_tripwire.py "$@"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
