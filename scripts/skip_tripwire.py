#!/usr/bin/env python
"""Skipped-test tripwire: fail CI if the suite silently starts skipping.

``tests/test_sharding.py`` and ``tests/test_roofline.py`` guard their
imports with ``pytest.importorskip("repro.dist...")`` so stripped-down
checkouts collect cleanly -- which also means a typo that breaks the
``repro.dist`` import would turn both files back into silent skips and
CI would stay green.  This script runs collection (``pytest --co -q``),
parses the summary, and asserts:

  * no collection errors,
  * collection-level skips stay within MAX_COLLECTION_SKIPS (0 on CPU;
    every known conditional skip in this suite happens at runtime, not
    collection),
  * at least MIN_COLLECTED tests exist (the suite cannot quietly
    shrink).

Run via ``./scripts/check.sh --tripwire`` (local and CI are the same
command).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

# Budget: collection-level skips allowed on a CPU runner.  The repo's
# only conditional skips (TPU-only kernel paths, the vlm prefill case in
# test_models_smoke.py) trigger at *runtime*; at collection the count
# must be exactly 0 -- any increase means an import regression.
MAX_COLLECTION_SKIPS = 0
# Collected-test floor (202 at the time of writing); catches the suite
# silently losing whole files without tracking every addition.
MIN_COLLECTED = 200


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "--co", "-q"],
        capture_output=True, text=True, env=env)
    tail = "\n".join(r.stdout.strip().splitlines()[-5:])

    m = re.search(r"(\d+)\s+tests? collected", r.stdout)
    collected = int(m.group(1)) if m else 0
    skipped = 0
    sm = re.search(r"(\d+)\s+skipped", r.stdout)
    if sm:
        skipped = int(sm.group(1))
    errors = 0
    em = re.search(r"(\d+)\s+errors?", r.stdout)
    if em:
        errors = int(em.group(1))

    problems = []
    if r.returncode not in (0,):
        problems.append(f"pytest --co exited {r.returncode}")
    if errors:
        problems.append(f"{errors} collection error(s)")
    if skipped > MAX_COLLECTION_SKIPS:
        problems.append(
            f"{skipped} collection-level skip(s) > budget "
            f"{MAX_COLLECTION_SKIPS} -- did a repro.* import break? "
            "(that is how repro.dist tests would silently re-skip)")
    if collected < MIN_COLLECTED:
        problems.append(
            f"only {collected} tests collected (< floor {MIN_COLLECTED})")

    if problems:
        print("skip tripwire FAILED:", "; ".join(problems))
        print("--- pytest --co tail ---")
        print(tail)
        if r.stderr.strip():
            print(r.stderr.strip()[-2000:])
        return 1
    print(f"skip tripwire ok: {collected} collected, {skipped} "
          f"collection skips (budget {MAX_COLLECTION_SKIPS})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
