#!/usr/bin/env python
"""Perf regression gate: compare BENCH_PR.json against the base branch.

CI downloads the ``bench-pr`` artifact from the most recent successful
run on the base branch and calls::

    python scripts/compare_bench.py --prev prev/BENCH_PR.json \
        --cur BENCH_PR.json --max-regression 0.25

Gated metrics (the kernels-backend serving hot paths plus the
scheduler's request-latency behavior):

  * ``tpot_quamba_kernels_ms``        -- lower is better.  Renamed
    from ``tpot_quamba_kernels_us`` (same measurement, now reported in
    milliseconds); baselines that predate the rename are read through
    ``RENAMES`` so the gate keeps comparing across the transition.
  * ``prefill_chunked_tokens_per_s``  -- higher is better
  * ``serve.spec_decode.tokens_per_s`` -- higher is better (end-to-end
    speculative-decoding throughput on the kernel backend; guards the
    fused draft-scan + multi-token-verify path against regressions)
  * ``engine_prefill.prefill_dispatches`` -- lower is better, and being
    a dispatch COUNT it is deterministic: unlike the wall-clock metrics
    (which shared CI runners can wobble), any increase is a real
    regression, so it gets a zero-tolerance threshold.
  * ``serve.ttft_ms.mean``            -- lower is better (per-request
    time-to-first-token through the scheduler; covers admission +
    prefill latency, not just the decode inner loop)
  * ``serve.prefix_cache.ttft_ms_hit.mean`` -- lower is better (TTFT
    of requests whose prompt prefix was restored from the state cache;
    the serving win prefix caching exists for).  This is a ~15 ms mean
    over few samples on shared runners, so it gets a loose 100%
    threshold: the failure mode it guards against -- the cache
    silently stops hitting and requests re-prefill -- is a ~100x
    regression, far above any timer wobble.
  * ``w4a8.tpot_kernels_ms`` -- lower is better (decode TPOT of the
    ``quamba-w4a8`` preset executing the nibble-packed ``int4_matmul``
    kernel, i.e. the real kernels backend, not the qdq oracle).
  * ``w4a8.matmul_weight_bytes_ratio`` -- lower is better and
    deterministic (packed int4 bytes / int8 bytes over the matmul
    weight sites, ~0.5 by construction), so it gets the zero-tolerance
    threshold: any growth means nibble packing silently stopped.
  * ``qat.w4a4.recovery`` -- higher is better (fraction of the
    ``quamba-w4a4`` PTQ eval-loss gap recovered by the QAT fine-tune;
    loose 50% band: it guards the STE gradient path going dead, which
    collapses recovery to ~0, not seed-to-seed training wobble).
  * ``serve.ttft_ms.p95`` and ``serve.loadgen.ttft_ms.p99`` -- lower is
    better (TAIL latency: the mean hides convoy effects and bursty
    queueing that the p95/p99 expose; the loadgen p99 comes from the
    trace-driven open-loop run).  Small-sample percentiles on shared
    runners get the same loose 100% threshold as the cache TTFT.
  * ``serve.disagg.ttft_ms.p95`` -- lower is better (TTFT tail through
    the disaggregated prefill/decode split; this path pays the
    snapshot pack/ship/restore on admission, so transport bloat or a
    broken zero-prefill restore surfaces here first).

The ``tpot_quamba_kernels_us`` producing alias is gone (one release
after the rename, as promised); ``RENAMES`` still bridges baselines
that predate the rename and is dropped once no archived baseline
carries the legacy key.

Forward compatibility is deliberate: the gate reads ONLY the dotted
keys above and ignores everything else in either file, so a newer
BENCH_PR.json with keys this script has never heard of (or a metric
whose value is a dict/string/None, or a top-level ``run_meta`` stamp)
can never crash the gate -- unknown structure skips with a note.  A timing metric regressing by more than
``--max-regression`` (fraction, default 0.25) fails the job.  Missing
previous artifact (first run on a branch, expired artifact) or missing
metrics skip gracefully with exit 0 -- the gate only ever compares like
with like.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

# (dotted key, higher_is_better, max_regression_override_or_None)
GATED = (
    ("tpot_quamba_kernels_ms", False, None),
    ("prefill_chunked_tokens_per_s", True, None),
    ("engine_prefill.prefill_dispatches", False, 0.0),
    ("serve.ttft_ms.mean", False, None),
    ("serve.ttft_ms.p95", False, 1.0),
    ("serve.prefix_cache.ttft_ms_hit.mean", False, 1.0),
    # higher-is-better regressions cap at 100% (throughput can only
    # fall to zero), so the loose small-sample threshold here is 50%:
    # worse than half the baseline throughput fails
    ("serve.spec_decode.tokens_per_s", True, 0.5),
    ("serve.loadgen.ttft_ms.p99", False, 1.0),
    # disaggregated serving TTFT tail: includes the snapshot transfer
    # on the admission path, so a transport regression shows up here;
    # small-sample percentile -> the loose 100% threshold
    ("serve.disagg.ttft_ms.p95", False, 1.0),
    # W4A8 on the int4-matmul kernels backend (PR 8).  The byte ratio
    # is a deterministic storage fact (nibble packing halves matmul
    # weight bytes), so like the dispatch count it gets zero tolerance:
    # any growth means packing silently stopped happening.
    ("w4a8.tpot_kernels_ms", False, None),
    ("w4a8.matmul_weight_bytes_ratio", False, 0.0),
    # QAT recovery on the headline sub-8-bit preset (PR 10): fraction
    # of the w4a4 PTQ eval-loss gap closed by the short fine-tune.
    # Higher is better; training noise across runners makes the ratio
    # wobble, so the band is loose (50%) -- the failure it guards
    # against is the STE gradient path silently breaking, which drops
    # recovery to ~0, far below any seed-to-seed wobble.
    ("qat.w4a4.recovery", True, 0.5),
)

# renamed metrics: canonical key -> (legacy key, scale legacy by).
# When the canonical key is absent (a baseline produced before the
# rename), the gate falls back to the legacy key converted into the
# canonical unit, so the transition release still compares like with
# like.  Drop entries here one release after the producing side drops
# its alias.
RENAMES = {
    "tpot_quamba_kernels_ms": ("tpot_quamba_kernels_us", 1e-3),
}


def _lookup(d, dotted):
    for part in dotted.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def _lookup_renamed(d, dotted):
    """_lookup plus the RENAMES fallback for pre-rename baselines."""
    v = _lookup(d, dotted)
    if v is not None or dotted not in RENAMES:
        return v
    legacy_key, scale = RENAMES[dotted]
    legacy = _lookup(d, legacy_key)
    if legacy is None:
        return None
    try:
        return float(legacy) * scale
    except (TypeError, ValueError):
        return None


def gate(prev: dict, cur: dict, max_regression: float,
         gated=GATED) -> List[str]:
    """Compare the gated metrics; returns failure strings (empty = ok).

    Tolerant by construction: keys absent from either side, non-numeric
    values, and non-positive baselines all skip instead of raising.
    """
    failures: List[str] = []
    for key, higher_better, override in gated:
        pv, cv = _lookup_renamed(prev, key), _lookup_renamed(cur, key)
        if pv is None or cv is None:
            print(f"perf gate: {key}: absent in prev or cur; skipping")
            continue
        try:
            p, c = float(pv), float(cv)
        except (TypeError, ValueError):
            print(f"perf gate: {key}: non-numeric value "
                  f"(prev={pv!r}, cur={cv!r}); skipping")
            continue
        if p <= 0:
            continue
        allowed = max_regression if override is None else override
        # regression fraction, positive = worse
        reg = (c - p) / p if not higher_better else (p - c) / p
        arrow = "worse" if reg > 0 else "better"
        print(f"perf gate: {key}: prev={p:.1f} cur={c:.1f} "
              f"({abs(reg) * 100:.1f}% {arrow})")
        if reg > allowed:
            failures.append(
                f"{key} regressed {reg * 100:.1f}% "
                f"(> {allowed * 100:.0f}% allowed)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", required=True)
    ap.add_argument("--cur", required=True)
    ap.add_argument("--max-regression", type=float, default=0.25)
    args = ap.parse_args()

    if not os.path.exists(args.prev):
        print(f"perf gate: no previous benchmark at {args.prev}; "
              "skipping (first run on this base?)")
        return 0
    try:
        with open(args.prev) as f:
            prev = json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf gate: unreadable previous benchmark ({e}); skipping")
        return 0
    with open(args.cur) as f:
        cur = json.load(f)

    failures = gate(prev, cur, args.max_regression)
    if failures:
        print("perf gate FAILED: " + "; ".join(failures))
        return 1
    print("perf gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
