"""End-to-end serving driver: a mixed request stream against a
Quamba-quantized SSM through the request-centric engine.

Trains a small model (or restores the benchmark checkpoint), quantizes
it with the paper's recipe, then serves requests with heterogeneous
``SamplingParams`` (greedy, temperature/top-k/top-p, a pinned seed), a
cancellation, and one request consumed token-by-token through its
stream.  Per-request TTFT/TPOT/queue-time and engine throughput come
from the metrics recorder -- the numbers the paper's 1.7x latency claim
is about.

Run:  PYTHONPATH=src:. python examples/serve_quantized.py [--requests 12]
"""
from __future__ import annotations

import argparse

from benchmarks.common import calibration_stats, quantized_model, \
    trained_model
from repro.serve import SamplingParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    # "quamba-kernels" runs the int8 Pallas execution backend (native on
    # TPU; interpret mode -- slow but identical -- off-TPU)
    ap.add_argument("--quant", default="quamba",
                    choices=["fp", "quamba", "quamba-kernels", "static",
                             "dynamic"])
    ap.add_argument("--prefill-chunk", type=int, default=128)
    ap.add_argument("--policy", default=None,
                    choices=["fcfs", "priority", "cache-aware"])
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="enable prefix state caching (requests share "
                         "a 24-token prompt head to exercise it)")
    args = ap.parse_args()

    cfg, params = trained_model()
    stats = (calibration_stats(cfg, params)
             if args.quant != "fp" else None)
    model = quantized_model(cfg, params, stats, args.quant)
    # prompts longer than one token prefill through the sequence path in
    # chunks of --prefill-chunk (one dispatch per chunk, not per token)
    eng = model.engine(max_batch=4, max_len=256,
                       prefill_chunk=args.prefill_chunk,
                       scheduler=args.policy,
                       prefix_cache_mb=(args.prefix_cache_mb or None))
    shared = ([(3 * j + 1) % cfg.vocab_size for j in range(24)]
              if args.prefix_cache_mb else [])

    # a heterogeneous batch: greedy, sampled (top-k/top-p), pinned seed
    def sp_for(i: int) -> SamplingParams:
        if i % 3 == 0:
            return SamplingParams(max_tokens=args.max_new)     # greedy
        if i % 3 == 1:
            return SamplingParams(temperature=0.7, top_k=50, top_p=0.9,
                                  max_tokens=args.max_new)
        return SamplingParams(temperature=1.0, top_p=0.8, seed=1000 + i,
                              max_tokens=args.max_new)

    states = [eng.add_request(
        shared + [(7 * i + j) % cfg.vocab_size for j in range(2 + i % 5)],
        sp_for(i), request_id=f"demo-{i}", priority=i % 3)
        for i in range(args.requests)]

    # cancel one mid-flight: two steps in, request 1 is evicted and its
    # slot goes back to the queue
    eng.step()
    eng.step()
    eng.cancel("demo-1")

    # consume request 0 incrementally -- iterating the stream pumps the
    # engine, so this also drives everyone else forward
    print("demo-0 streams:", end=" ", flush=True)
    for tok in states[0].stream:
        print(tok, end=" ", flush=True)
    print()
    eng.run()                      # finish the rest

    mj = eng.metrics_json()
    e = mj["summary"]
    print(f"served {len(states)} requests "
          f"({mj['engine']['tokens_generated']} tokens, "
          f"{mj['engine']['requests_cancelled']} cancelled) "
          f"[{args.quant}, {args.policy}]")
    print(f"TTFT mean {e['ttft_ms']['mean']:.1f} ms  "
          f"TPOT mean {e['tpot_ms']['mean']:.1f} ms  "
          f"queue mean {e['queue_time_ms']['mean']:.1f} ms  "
          f"throughput {mj['engine']['tokens_per_s']:.1f} tok/s")
    pc = mj.get("prefix_cache")
    if pc:
        print(f"prefix cache: hit rate {pc['hit_rate']}, "
              f"{pc['tokens_reused']} tokens reused, "
              f"{pc['entries']} entries / {pc['bytes_in_use']} B")
    for st in states[:3]:
        m = mj["requests"][st.request_id]
        ttft = m["ttft_ms"]
        print(f"  {st.request_id}: {st.finish_reason.value if st.finish_reason else '?'}"
              f" tokens={list(st.token_ids)}"
              f" ttft={'%.1f ms' % ttft if ttft is not None else 'n/a'}")


if __name__ == "__main__":
    main()
