"""End-to-end serving driver: batched requests against a Quamba-quantized
SSM through the continuous-batching engine (deliverable b).

Trains a small model (or restores the benchmark checkpoint), quantizes it
with the paper's recipe, then serves a stream of batched requests with
mixed prompt lengths and measures TPOT.

Run:  PYTHONPATH=src:. python examples/serve_quantized.py [--requests 12]
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import calibration_stats, quantized_model, \
    trained_model
from repro.serve import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    # "quamba-kernels" runs the int8 Pallas execution backend (native on
    # TPU; interpret mode -- slow but identical -- off-TPU)
    ap.add_argument("--quant", default="quamba",
                    choices=["fp", "quamba", "quamba-kernels", "static",
                             "dynamic"])
    ap.add_argument("--prefill-chunk", type=int, default=128)
    args = ap.parse_args()

    cfg, params = trained_model()
    stats = (calibration_stats(cfg, params)
             if args.quant != "fp" else None)
    model = quantized_model(cfg, params, stats, args.quant)
    # prompts longer than one token prefill through the sequence path in
    # chunks of --prefill-chunk (one dispatch per chunk, not per token)
    eng = model.engine(max_batch=4, max_len=256,
                       prefill_chunk=args.prefill_chunk)
    reqs = [Request(uid=i, prompt=[(7 * i + j) % cfg.vocab_size
                                   for j in range(2 + i % 5)],
                    max_new_tokens=args.max_new,
                    temperature=0.0 if i % 2 else 0.7)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    steps = 0
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests ({tokens} tokens) in {dt:.2f}s "
          f"over {steps} engine steps [{args.quant}]")
    print(f"TPOT ~ {dt / max(steps,1) * 1e3:.1f} ms/step, "
          f"throughput {tokens / dt:.1f} tok/s")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt={r.prompt} -> {r.output}")


if __name__ == "__main__":
    main()
