"""End-to-end training driver: a ~100M-parameter Mamba trained for a few
hundred steps with the fault-tolerant loop (deliverable b).

Defaults are sized for this CPU container (--layers 24 --width 768 is the
real mamba-130m backbone; pass --small for a quick run).

Run:  PYTHONPATH=src python examples/train_100m.py --steps 200 --small
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, scale_down
from repro.data import batches
from repro.optim import OptimConfig
from repro.train import LoopConfig, init_train_state, make_train_step, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/train_100m")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config("mamba-130m")
    cfg = dataclasses.replace(cfg, dtype="float32")
    if args.small:
        cfg = scale_down(cfg, layers=4, width=256, vocab=4096)
        args.seq = min(args.seq, 256)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    state = init_train_state(jax.random.PRNGKey(0), cfg,
                             compress_grads=args.compress_grads)
    step = make_train_step(
        cfg, OptimConfig(lr=6e-4, warmup_steps=args.steps // 10,
                         total_steps=args.steps),
        remat=True, compress_grads=args.compress_grads)
    loop = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(20, args.steps // 5), log_every=10)
    data = lambda s0: batches(cfg.vocab_size, args.batch, args.seq,
                              seed=13, start_step=s0)
    metrics = train(loop, step, state, data)
    print("final:", {k: float(v) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
