"""Quickstart: the full Quamba pipeline on a laptop-scale Mamba LM,
driven entirely through the public API (``repro.api``).

1. train a small Mamba on the synthetic corpus
2. build quantized artifacts with ``api.Quantizer``: calibration scales
   come from 512-ish held-out samples (paper §5.1) and the Quamba recipe
   (percentile x-clip + Hadamard-rotated output) is applied site-by-site
   via the family's registered site map
3. compare perplexity: FP vs naive-static vs Quamba, all through
   ``QuantizedModel.loss``
4. save the artifact and reload it (atomic, crc-checked)
5. generate tokens with the quantized model through the serving engine

The legacy free functions (``run_calibration`` / ``quantize_model`` /
``make_qctx``) still exist but are deprecated shims; new code should use
``api.Quantizer(cfg, spec).calibrate(batches).quantize(params)``, which
returns a ``QuantizedModel`` bundling (params, qdata, spec, cfg) with
``forward`` / ``loss`` / ``engine`` / ``save`` / ``load``.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 150]
"""
from __future__ import annotations

import argparse
import math
import os
import tempfile

import jax

from repro import api
from repro.configs import get_config, scale_down
from repro.data import batches, eval_batches
from repro.optim import OptimConfig
from repro.train import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    cfg = scale_down(get_config("mamba-130m"), layers=3, width=192,
                     vocab=1024)
    print(f"[1/5] training {cfg.name} (reduced: {cfg.n_layers}L "
          f"d={cfg.d_model}) for {args.steps} steps")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=2e-3, warmup_steps=20, total_steps=args.steps)))
    for i, b in enumerate(batches(cfg.vocab_size, 16, 128, seed=11,
                                  num_steps=args.steps)):
        state, m = step(state, b)
        if (i + 1) % 50 == 0:
            print(f"    step {i+1}: loss {float(m['loss']):.3f}")
    params = state["params"]

    print("[2/5] calibrating + quantizing (Quamba W8A8 + static baseline)")
    calib = list(eval_batches(cfg.vocab_size, 8, 128, 6, seed=777))
    stats = api.calibration_stats(cfg, params, calib)
    q_model = api.Quantizer(cfg, "quamba").with_stats(stats) \
        .quantize(params)
    s_model = api.Quantizer(cfg, "static").with_stats(stats) \
        .quantize(params)
    fp_model = api.Quantizer(cfg, "fp").quantize(params)

    print("[3/5] perplexity comparison")
    evalb = list(eval_batches(cfg.vocab_size, 16, 128, 4, seed=999))

    def ppl(model: api.QuantizedModel) -> float:
        import numpy as np
        from repro.models import loss_fn
        # params ride as a jit argument, not as baked-in XLA constants
        qctx = model.qctx()
        f = jax.jit(lambda p, b: loss_fn(p, cfg, b, qctx=qctx)[0])
        return math.exp(float(np.mean(
            [float(f(model.params, b)) for b in evalb])))

    print(f"    fp32          : {ppl(fp_model):.3f}")
    print(f"    static  W8A8  : {ppl(s_model):.3f}")
    print(f"    quamba  W8A8  : {ppl(q_model):.3f}")

    print("[4/5] save / load round trip")
    path = os.path.join(tempfile.mkdtemp(prefix="quamba_"), "artifact")
    q_model.save(path)
    q_model = api.load(path)
    print(f"    reloaded {q_model} from {path}")

    print("[5/5] generating with the quantized model")
    # Execution backend: the default spec runs the qdq fake-quant oracle.
    # spec="quamba-kernels" (or model.qctx(backend="kernels")) feeds int8
    # activations straight to the Pallas kernels -- the deployed dataflow,
    # native on TPU and interpret-mode (slow, identical numerics) off-TPU.
    outs = q_model.generate([[1, 2, 3], [42, 7]], max_new_tokens=12,
                            max_len=64)
    for i, o in enumerate(outs):
        print(f"    prompt {i}: {o}")
    print("done.")


if __name__ == "__main__":
    main()
