"""Quickstart: the full Quamba pipeline on a laptop-scale Mamba LM.

1. train a small Mamba on the synthetic corpus
2. calibrate static scales on 512-ish held-out samples (paper §5.1)
3. quantize with the Quamba recipe (percentile x-clip + Hadamard y)
4. compare perplexity: FP vs naive-static vs Quamba
5. generate tokens with the quantized model through the serving engine

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 150]
"""
from __future__ import annotations

import argparse
import math

import jax

from repro.configs import get_config, scale_down
from repro.data import batches, eval_batches
from repro.models import forward, loss_fn
from repro.models.quantize import make_qctx, quantize_model
from repro.optim import OptimConfig
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import get_spec
from repro.serve import generate
from repro.train import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    cfg = scale_down(get_config("mamba-130m"), layers=3, width=192,
                     vocab=1024)
    print(f"[1/5] training {cfg.name} (reduced: {cfg.n_layers}L "
          f"d={cfg.d_model}) for {args.steps} steps")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=2e-3, warmup_steps=20, total_steps=args.steps)))
    for i, b in enumerate(batches(cfg.vocab_size, 16, 128, seed=11,
                                  num_steps=args.steps)):
        state, m = step(state, b)
        if (i + 1) % 50 == 0:
            print(f"    step {i+1}: loss {float(m['loss']):.3f}")
    params = state["params"]

    print("[2/5] calibrating activation scales")
    calib = eval_batches(cfg.vocab_size, 8, 128, 6, seed=777)
    stats = run_calibration(
        lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"}),
        params, calib)

    print("[3/5] quantizing (Quamba W8A8) + naive static baseline")
    q_spec = get_spec("quamba")
    q_params, q_data = quantize_model(params, stats, cfg, q_spec)
    s_spec = get_spec("static")
    s_params, s_data = quantize_model(params, stats, cfg, s_spec)

    print("[4/5] perplexity comparison")
    evalb = eval_batches(cfg.vocab_size, 16, 128, 4, seed=999)

    def ppl(p, qctx=None):
        import numpy as np
        f = jax.jit(lambda pp, b: loss_fn(pp, cfg, b, qctx=qctx)[0])
        return math.exp(float(np.mean([float(f(p, b)) for b in evalb])))

    print(f"    fp32          : {ppl(params):.3f}")
    print(f"    static  W8A8  : {ppl(s_params, make_qctx(s_spec, s_data)):.3f}")
    print(f"    quamba  W8A8  : {ppl(q_params, make_qctx(q_spec, q_data)):.3f}")

    print("[5/5] generating with the quantized model")
    outs = generate(q_params, cfg, [[1, 2, 3], [42, 7]],
                    max_new_tokens=12, qctx=make_qctx(q_spec, q_data),
                    max_len=64)
    for i, o in enumerate(outs):
        print(f"    prompt {i}: {o}")
    print("done.")


if __name__ == "__main__":
    main()
