"""Theorem 4.1 / Figure 5 demo: quantization error of LTI SSMs is bounded.

Prints an ASCII plot of measured error vs the (corrected) analytic bound.
Run:  PYTHONPATH=src python examples/error_bound_demo.py
"""
from __future__ import annotations

import numpy as np

from repro.quant.errors import (simulate_quantized_lti,
                                simulate_theorem_system)


def ascii_plot(ys, width=60, label=""):
    m = max(float(max(ys)), 1e-12)
    for i in range(0, len(ys), max(1, len(ys) // 12)):
        bar = "#" * int(width * ys[i] / m)
        print(f"  t={i:4d} |{bar}")
    print(f"  (max={m:.3e}) {label}")


def main() -> None:
    print("== Theorem A.1 system: h[t] = e^(t-T) h[t-1] + b x[t] ==")
    r = simulate_theorem_system(steps=120)
    ascii_plot(r["err"], label="|h - h_quant|")
    from repro.quant.errors import CORRECTED_CONSTANT
    beps = 0.7 * 0.01
    print(f"corrected uniform bound b*eps*sum_k e^(-k(k-1)/2) = "
          f"{beps * CORRECTED_CONSTANT:.4e}; "
          f"measured max = {r['err'].max():.4e}")

    for measure in ("legt", "legs"):
        print(f"\n== HiPPO-{measure.upper()} materialized SSM (Fig. 5) ==")
        rr = simulate_quantized_lti(measure, steps=200)
        ascii_plot(rr["output_err"], label=f"Mean|y - y_quant| ({measure})")
        print("errors remain bounded as t grows: "
              f"{bool(rr['output_err'][100:].max() <= 2 * rr['output_err'][:100].max())}")


if __name__ == "__main__":
    main()
